"""Compiler passes of the integration flow (paper §3.3), as declarative
rule tables over the pattern-rewrite engine plus a handful of function
passes, composed into per-mode pipelines by ``frontend_passes`` /
``passes_for_mode`` and run by the ``PassManager``.

Legalization (the Frontend Configurator): the quantized multi-op sequence
(dense -> bias_add -> requantize -> clip) and the float sequences
(dense -> bias_add [-> activation]) rewrite into *generalized* operators
so TIR-level lowering sees a single op (§3.3).  On top of it, the
optimization layer the hand-rolled traversals could not express cheaply:

  * ``fold_transpose``   — transpose∘transpose composition and folding a
    non-constant matrix transpose into the consuming dense
    (``transpose_b`` — the accelerator reads the operand transposed);
  * ``fuse_residual``    — add-of-generalized-op becomes a fused residual
    epilogue (transformer skip connections stay on the accelerator);
  * ``fuse_conv_pool``   — max_pool2d over a generalized conv2d becomes a
    fused pooling epilogue;
  * ``cse``              — common-subexpression elimination (structural,
    including value-equal constants);
  * ``dce``              — no-effect-node elimination (identity
    transposes/reshapes, full-range clips).  Classic unreachable-code DCE
    is implicit in this IR: graphs are defined by reachability from their
    outputs, so rewrites can never leave dead nodes behind.

``fold_constants`` evaluates constant subgraphs at compile time — the pass
the paper had to fight TVM for; the naive BYOC mode skips the whole
optimization pipeline and pays at run time, reproducing Table 2's blowup.
``partition`` marks accelerator-supported operators (BYOC-style) last.

Accelerator descriptions can contribute target-specific patterns via
``AcceleratorDescription.register_rewrite_pattern`` — they run right after
the generic legalization rules.
"""

from __future__ import annotations

import numpy as np

from repro.core.accel import AcceleratorDescription
from repro.core.collective import ShardSpec
from repro.core.ir import Graph, Node, const, execute_node
from repro.core import ir
from repro.core.pass_manager import (
    GraphPass,
    PassContext,
    PassManager,
    rewrite_pass,
)
from repro.core.rewrite import Match, P, any_, apply_rules, rule

_CORE_OPS = ("dense", "conv2d")
_GENERALIZED = ("generalized_dense", "generalized_conv2d")


def _gen_op_for(core: Node) -> str:
    return "generalized_dense" if core.op == "dense" else "generalized_conv2d"


# ---------------------------------------------------------------------------
# Legalization rules (longest chain first; the engine anchors downstream-
# first, so the quantized chain wins over its bias_add sub-pattern).
# ---------------------------------------------------------------------------


@rule(
    "fuse-quantized-epilogue",
    P(
        "clip",
        P(
            "requantize",
            P("bias_add", P(_CORE_OPS, capture="core"), any_("bias")),
            capture="rq",
        ),
    ),
)
def _fuse_quantized(m: Match, graph: Graph) -> Node | None:
    """clip(requantize(bias_add(dense|conv2d))) -> one generalized op."""
    core, rq, root = m["core"], m["rq"], m.root
    return Node(
        _gen_op_for(core),
        [core.inputs[0], core.inputs[1], m["bias"]],
        {
            **core.attrs,
            "quantized": True,
            "requant_scale": rq.attrs["scale"],
            "clip_lo": root.attrs["lo"],
            "clip_hi": root.attrs["hi"],
        },
        shape=root.shape,
        dtype=root.dtype,
    )


@rule(
    "fuse-activation",
    P(
        ("relu", "gelu"),
        P("bias_add", P(_CORE_OPS, capture="core"), any_("bias")),
    ),
)
def _fuse_activation(m: Match, graph: Graph) -> Node | None:
    """activation(bias_add(dense|conv2d)) -> one generalized op."""
    core, root = m["core"], m.root
    return Node(
        _gen_op_for(core),
        [core.inputs[0], core.inputs[1], m["bias"]],
        {**core.attrs, "quantized": False, "activation": root.op},
        shape=root.shape,
        dtype=root.dtype,
    )


@rule(
    "fuse-bias",
    P("bias_add", P(_CORE_OPS, capture="core"), any_("bias")),
)
def _fuse_bias(m: Match, graph: Graph) -> Node | None:
    """bias_add(dense|conv2d) -> one generalized op (no epilogue)."""
    core, root = m["core"], m.root
    return Node(
        _gen_op_for(core),
        [core.inputs[0], core.inputs[1], m["bias"]],
        {**core.attrs, "quantized": False, "activation": None},
        shape=root.shape,
        dtype=root.dtype,
    )


LEGALIZE_RULES = (_fuse_quantized, _fuse_activation, _fuse_bias)


# ---------------------------------------------------------------------------
# Optimization rules.
# ---------------------------------------------------------------------------


@rule("fold-transpose-transpose", P("transpose", P("transpose", any_("src"), capture="inner")))
def _fold_transpose_transpose(m: Match, graph: Graph) -> Node | None:
    """transpose(transpose(x)) -> x (identity) or one composed transpose."""
    src, inner, root = m["src"], m["inner"], m.root
    p1 = inner.attrs["perm"]
    p2 = root.attrs["perm"]
    combined = tuple(p1[j] for j in p2)
    if combined == tuple(range(len(combined))):
        if src.shape != root.shape or src.dtype != root.dtype:
            return None
        return src
    return Node(
        "transpose",
        [src],
        {"perm": combined},
        shape=root.shape,
        dtype=root.dtype,
    )


@rule(
    "fold-transpose-into-dense",
    P("dense", any_("x"), P("transpose", any_("w"), capture="t")),
)
def _fold_transpose_into_dense(m: Match, graph: Graph) -> Node | None:
    """dense(x, transpose(w)) -> dense(x, w, transpose_b=True): the mapped
    executor reads the weight operand transposed (a free view on the host
    targets) instead of materializing a layout op.  Applies to the 2-D
    weight transpose and to the batched matmul's last-two-dims transpose
    (attention K^T with a leading batch dim).  Constant transposes are
    left alone — constant folding removes them entirely at compile time,
    which is strictly better than re-reading them transposed per run."""
    w, t, root = m["w"], m["t"], m.root
    if w is None or w.is_const() or len(w.shape) not in (2, 3):
        return None
    swap_last_two = (1, 0) if len(w.shape) == 2 else (0, 2, 1)
    if t.attrs["perm"] != swap_last_two or root.attrs.get("transpose_b"):
        return None
    return Node(
        "dense",
        [m["x"], w],
        {**root.attrs, "transpose_b": True},
        shape=root.shape,
        dtype=root.dtype,
    )


FOLD_TRANSPOSE_RULES = (_fold_transpose_transpose, _fold_transpose_into_dense)


def _residual_build(gen: Node, res: Node, root: Node) -> Node | None:
    if gen.attrs.get("residual"):
        return None  # one residual operand per op
    if gen.shape != root.shape or res.shape != root.shape:
        return None  # no broadcasting in the fused epilogue
    if gen.dtype != root.dtype:
        return None
    return Node(
        gen.op,
        [*gen.inputs, res],
        {**gen.attrs, "residual": True},
        shape=root.shape,
        dtype=root.dtype,
    )


@rule("fuse-residual", P("add", P(_GENERALIZED, capture="gen"), any_("res")))
def _fuse_residual_lhs(m: Match, graph: Graph) -> Node | None:
    """add(generalized_op, residual) -> fused residual epilogue."""
    return _residual_build(m["gen"], m["res"], m.root)


@rule("fuse-residual-rhs", P("add", any_("res"), P(_GENERALIZED, capture="gen")))
def _fuse_residual_rhs(m: Match, graph: Graph) -> Node | None:
    """add(residual, generalized_op) — addition commutes, same fusion."""
    if m["res"] is m["gen"]:
        return None
    return _residual_build(m["gen"], m["res"], m.root)


RESIDUAL_RULES = (_fuse_residual_lhs, _fuse_residual_rhs)


@rule("fuse-conv-pool", P("max_pool2d", P("generalized_conv2d", capture="conv")))
def _fuse_conv_pool(m: Match, graph: Graph) -> Node | None:
    """max_pool2d(generalized_conv2d) -> fused pooling epilogue.  The
    pooled shape becomes the node shape; the conv's own output shape is
    kept in the pool attrs so the executor can reshape before pooling."""
    conv, root = m["conv"], m.root
    if conv.attrs.get("pool") or conv.attrs.get("residual"):
        # residual-then-pool would reorder the epilogue stages; decline
        return None
    return Node(
        conv.op,
        list(conv.inputs),
        {
            **conv.attrs,
            "pool": {
                "size": root.attrs["size"],
                "stride": root.attrs["stride"],
                "conv_shape": tuple(conv.shape),
            },
        },
        shape=root.shape,
        dtype=root.dtype,
    )


CONV_POOL_RULES = (_fuse_conv_pool,)


# ---------------------------------------------------------------------------
# Function passes: constant folding, CSE, DCE, partitioning.
# ---------------------------------------------------------------------------


def _rewire(graph: Graph, replace: dict[Node, Node]) -> None:
    """Apply a node-replacement map over the whole graph in one sweep."""
    order = graph.toposort()
    for n in order:
        if n in replace:
            continue
        new_inputs = [
            replace.get(i, i) if i is not None else None for i in n.inputs
        ]
        if any(a is not b for a, b in zip(new_inputs, n.inputs)):
            n.inputs = new_inputs
    graph.outputs = [replace.get(o, o) for o in graph.outputs]
    graph.invalidate()


def _fold_constants(graph: Graph, ctx: PassContext | None = None) -> int:
    """Evaluate nodes whose inputs are all constants, in ONE topological
    sweep (inputs fold before their consumers are visited, so a whole
    constant chain collapses in a single pass).  Runs registered constant
    preprocessing (weight transpose/quantize) at compile time — the key
    enabler the paper identifies in §4."""
    folded: dict[Node, Node] = {}
    for n in graph.toposort():
        if n.op in ("input", "const") or n.op.startswith("generalized"):
            continue
        ins = [folded.get(i, i) if i is not None else None for i in n.inputs]
        if not ins or not all(i is not None and i.is_const() for i in ins):
            continue
        try:
            val = execute_node(n, [i.value for i in ins])
        except NotImplementedError:
            continue
        folded[n] = const(np.asarray(val), name=f"folded_{n.name}")
    if folded:
        _rewire(graph, folded)
    return len(folded)


def _freeze_attr(v):
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze_attr(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze_attr(x) for x in v)
    if isinstance(v, np.ndarray):
        return (v.dtype.str, v.shape, v.tobytes())
    return v


def _cse(graph: Graph, ctx: PassContext | None = None) -> int:
    """Common-subexpression elimination: structurally identical nodes
    (same op, same resolved inputs, same attrs/shape/dtype) and value-equal
    constants collapse onto one representative."""
    table: dict = {}
    replace: dict[Node, Node] = {}
    for n in graph.toposort():
        if n.op == "input":
            continue  # inputs are distinct feeds even when shapes agree
        if n.op == "const":
            key = ("const", n.dtype, n.shape, n.value.tobytes())
        else:
            ins = tuple(
                id(replace.get(i, i)) if i is not None else None for i in n.inputs
            )
            key = (n.op, ins, n.shape, n.dtype, _freeze_attr(n.attrs))
        try:
            canon = table.get(key)
        except TypeError:  # unhashable attr payload: leave the node alone
            continue
        if canon is not None:
            replace[n] = canon
        else:
            table[key] = n
    if replace:
        _rewire(graph, replace)
    return len(replace)


def _covers_dtype_range(dtype: str, lo, hi) -> bool:
    if not (dtype.startswith("int") or dtype.startswith("uint")):
        return False
    info = np.iinfo(dtype)
    return lo <= info.min and hi >= info.max


def _dce(graph: Graph, ctx: PassContext | None = None) -> int:
    """Dead-node elimination.  Unreachable nodes cannot exist in this IR
    (a graph IS its reachable set), so "dead" means *no effect*: identity
    transposes/reshapes and clips that cannot clip their dtype's range.
    Those still cost buffer slots and plan steps, so they go."""
    replace: dict[Node, Node] = {}
    for n in graph.toposort():
        if not n.inputs or n.inputs[0] is None:
            continue
        src = replace.get(n.inputs[0], n.inputs[0])
        if src.shape != n.shape or src.dtype != n.dtype:
            continue
        if n.op == "transpose" and n.attrs["perm"] == tuple(range(len(n.shape))):
            replace[n] = src
        elif n.op in ("reshape", "flatten"):
            replace[n] = src
        elif n.op == "clip" and _covers_dtype_range(
            n.dtype, n.attrs["lo"], n.attrs["hi"]
        ):
            replace[n] = src
    if replace:
        _rewire(graph, replace)
    return len(replace)


def _partition(graph: Graph, ctx: PassContext) -> int:
    """Mark accelerator-supported operators (BYOC-style partitioning)."""
    desc: AcceleratorDescription = ctx.desc
    supported = desc.supported_ops()
    marked = 0
    for n in graph.toposort():
        base = n.op.replace("generalized_", "")
        x = n.inputs[0] if n.inputs else None
        operand_dtype = x.dtype if x is not None else n.dtype
        if (
            base in supported
            and n.op != "input"
            and n.op not in ir.CACHE_OPS  # state stays host-resident
            and desc.supports_dtype(n.op, operand_dtype)
        ):
            n.target = "accel"
            marked += 1
        else:
            n.target = "host"
    return marked


# ---------------------------------------------------------------------------
# Shard partitioning (sharded ExecutionPlans, ``Target(devices=N)``).
# ---------------------------------------------------------------------------


def _shard_candidates(graph: Graph, desc: AcceleratorDescription) -> list[Node]:
    """Accelerator-eligible core ops in toposort order.  The POSITION in
    this list keys each node's collective group: per-shard graph clones
    (``ir.clone_graph``) preserve toposort order, so index ``i`` names the
    same logical node on every shard regardless of process-global node
    counters."""
    supported = desc.supported_ops()
    out = []
    for n in graph.toposort():
        base = n.op.replace("generalized_", "")
        if base not in ("dense", "conv2d"):
            continue
        x = n.inputs[0] if n.inputs else None
        dtype = x.dtype if x is not None else n.dtype
        if base in supported and desc.supports_dtype(n.op, dtype):
            out.append(n)
    return out


#: per-shard slice floor: a shard narrower than one SIMD-lane quantum pays
#: pure collective overhead for near-zero work, so such dims never split.
#: The floor is deliberately NOT the full tile alignment — a sub-tile
#: shard's accel work saturates at one padded tile (no win, no loss), but
#: the epilogues the gather sinks below (``_sink_gathers``) and the
#: narrower collective payloads still scale with 1/P.
_MIN_SHARD_DIM = 4


def _softmax_in_epilogue(n: Node, consumers: dict[Node, list[Node]]) -> bool:
    """True when ``n``'s sole-consumer elementwise epilogue chain reaches a
    softmax.  Softmax normalizes along the LAST axis, so a cols split's
    all_gather (axis -1) can never sink past it — but a rows split's
    axis-0 gather commutes with the whole chain, letting ``_sink_gathers``
    push the epilogues down to the 1/P slice."""
    cur = n
    while True:
        cs = consumers.get(cur, ())
        if len(cs) != 1:
            return False
        nxt = cs[0]
        if nxt.op not in _GATHER_SINK_OPS or tuple(nxt.shape) != tuple(
            cur.shape
        ):
            return False
        if nxt.op == "softmax":
            return True
        cur = nxt


def _plan_split(
    n: Node, mp: int, consumers: dict[Node, list[Node]]
) -> str | None:
    """Choose the tensor-parallel split of one core op, or None.

    * ``heads`` — the batched 3-D dense (both operands activations with a
      leading batch/heads dim): split the instance dim across shards.
    * ``cols``  — split the output-column (K) dim: disjoint weight columns
      per shard, partial outputs concatenate (no reduction, so nonlinear
      fused epilogues stay correct per shard).
    * ``rows``  — split GEMM rows of a 2-D input; preferred over ``cols``
      when the epilogue chain contains a softmax (see
      ``_softmax_in_epilogue``), the fallback otherwise.

    A split is only taken when the dim divides evenly AND the per-shard
    slice stays at or above ``_MIN_SHARD_DIM`` lanes.
    """
    base = n.op.replace("generalized_", "")
    if base == "dense":
        w = n.inputs[1]
        if len(w.shape) == 3:  # batched matmul: heads split
            b = n.inputs[0].shape[0]
            return "heads" if b % mp == 0 and b >= mp else None
        k = w.shape[0] if n.attrs.get("transpose_b") else w.shape[1]
        cols_ok = k % mp == 0 and k // mp >= _MIN_SHARD_DIM
        rows = n.inputs[0].shape[0] if len(n.inputs[0].shape) == 2 else 0
        rows_ok = bool(rows) and rows % mp == 0 and rows // mp >= _MIN_SHARD_DIM
        if rows_ok and (not cols_ok or _softmax_in_epilogue(n, consumers)):
            return "rows"
        return "cols" if cols_ok else None
    co = n.inputs[1].shape[-1]  # conv2d HWIO weights
    if co % mp == 0 and co // mp >= _MIN_SHARD_DIM:
        return "cols"
    return None


def _shard_operand(x: Node | None, axis: int, rank: int, parts: int) -> Node | None:
    """Slice one operand for this shard: constants slice at compile time
    (the folded weight panel never materializes fully on the shard),
    activations go through a shard_slice host op."""
    if x is None:
        return None
    if x.is_const():
        ax = axis % x.value.ndim
        size = x.value.shape[ax] // parts
        idx = [slice(None)] * x.value.ndim
        idx[ax] = slice(rank * size, (rank + 1) * size)
        return const(
            np.ascontiguousarray(x.value[tuple(idx)]),
            name=f"{x.name}_shard{rank}",
        )
    return ir.shard_slice(x, axis, rank, parts)


def _shard_node(n: Node, split: str, spec: ShardSpec, group: str) -> Node:
    """Build the sharded clone of ``n`` + its re-materializing all_gather."""
    mp, rank = spec.model, spec.model_rank
    base = n.op.replace("generalized_", "")
    inputs = list(n.inputs)
    attrs = {**n.attrs}
    if split == "heads":
        inputs[0] = _shard_operand(inputs[0], 0, rank, mp)
        inputs[1] = _shard_operand(inputs[1], 0, rank, mp)
        shape = (n.shape[0] // mp, *n.shape[1:])
        gather_axis = 0
    elif split == "rows":
        inputs[0] = _shard_operand(inputs[0], 0, rank, mp)
        if attrs.get("residual") and len(inputs) > 3:
            inputs[3] = _shard_operand(inputs[3], 0, rank, mp)
        shape = (n.shape[0] // mp, *n.shape[1:])
        gather_axis = 0
    else:  # cols
        if base == "dense":
            w_axis = 0 if attrs.get("transpose_b") else 1
        else:
            w_axis = len(inputs[1].shape) - 1  # conv2d: HWIO output channels
        inputs[1] = _shard_operand(inputs[1], w_axis, rank, mp)
        if len(inputs) > 2:  # generalized op bias (may be None)
            inputs[2] = _shard_operand(inputs[2], 0, rank, mp)
        if attrs.get("residual") and len(inputs) > 3:
            inputs[3] = _shard_operand(inputs[3], -1, rank, mp)
        if "pool" in attrs:  # fused pooling: the conv's own shape narrows
            cs = attrs["pool"]["conv_shape"]
            attrs["pool"] = {
                **attrs["pool"],
                "conv_shape": (*cs[:-1], cs[-1] // mp),
            }
        shape = (*n.shape[:-1], n.shape[-1] // mp)
        gather_axis = -1
    sharded = Node(n.op, inputs, attrs, shape=shape, dtype=n.dtype)
    return ir.all_gather(
        sharded, gather_axis, group=group, rank=rank, parts=mp
    )


#: unary elementwise ops an all_gather may sink below: applying the op to
#: the gathered tensor equals gathering the op applied per-slice, provided
#: the op never mixes elements ACROSS the gather axis (softmax normalizes
#: along the last axis, so it only commutes with gathers on other axes).
_GATHER_SINK_OPS = {
    "requantize",
    "quantize",
    "dequantize",
    "clip",
    "relu",
    "gelu",
    "softmax",
}


def _sink_gathers(graph: Graph) -> int:
    """Push all_gathers below sole-consumer elementwise epilogue chains:
    ``ew(all_gather(x))`` -> ``all_gather(ew(x))``.  The epilogue then runs
    on the shard's 1/P slice instead of the full gathered tensor — without
    this, a host-epilogue-heavy model (the transformer's quantize/softmax/
    requantize chain) is Amdahl-capped no matter how well its GEMMs split.
    The collective's group id rides along unchanged, so the rendezvous
    still pairs the same logical gather across shards; payloads that sink
    below a (re)quantize also shrink to the narrow dtype."""
    changed = 0
    while True:
        consumers: dict[Node, list[Node]] = {}
        for n in graph.toposort():
            for i in n.inputs:
                if i is not None:
                    consumers.setdefault(i, []).append(n)
        moved = False
        for n in graph.toposort():
            if n.op not in _GATHER_SINK_OPS:
                continue
            g = n.inputs[0]
            if g is None or g.op != "all_gather":
                continue
            if len(consumers.get(g, ())) != 1 or any(
                o is g for o in graph.outputs
            ):
                continue
            if tuple(n.shape) != tuple(g.shape):
                continue  # not elementwise w.r.t. this tensor
            axis = g.attrs["axis"] % len(g.shape)
            if n.op == "softmax" and axis == len(n.shape) - 1:
                continue  # softmax normalizes along the gathered axis
            parts = g.attrs["parts"]
            shard_shape = list(n.shape)
            shard_shape[axis] //= parts
            inner = Node(
                n.op,
                [g.inputs[0]],
                dict(n.attrs),
                shape=tuple(shard_shape),
                dtype=n.dtype,
            )
            sunk = ir.all_gather(
                inner,
                axis,
                group=g.attrs["group"],
                rank=g.attrs["rank"],
                parts=parts,
            )
            graph.replace_node(n, sunk)
            changed += 1
            moved = True
            break  # the consumer map is stale after a rewrite
        if not moved:
            return changed


def make_shard_pass(spec: ShardSpec) -> GraphPass:
    """The shard-partitioning pass of ``Target(devices=N)`` compiles: runs
    right before ``partition`` on each shard's graph clone.  Tensor-
    parallel (mesh ``model`` axis): every accelerator-eligible core op that
    benefits is rewritten to compute this shard's slice and immediately
    ``all_gather`` the full value back (split -> compute -> gather, no SPMD
    propagation — every visible tensor stays replicated, so the rest of
    the pipeline is untouched).  Data-parallel (mesh ``data`` axis): the
    api layer retraces each batch bucket at ``bucket/data`` rows and this
    pass appends one batch-axis all_gather per graph output."""

    def _shard(graph: Graph, ctx: PassContext) -> int:
        desc: AcceleratorDescription = ctx.desc
        stateful = [n.name for n in graph.toposort() if n.op in ir.CACHE_OPS]
        if stateful:
            # capability negotiation: KV-cache state is host-resident and
            # per-request — splitting it across a mesh would need state
            # placement the runtime doesn't model yet.  Refuse loudly
            # rather than emit silently-wrong replicated plans.
            raise ValueError(
                "stateful decode graphs cannot be shard-partitioned: "
                f"graph {graph.name!r} carries KV-cache ops {stateful}; "
                "compile with Target(devices=1) and scale decode via "
                "repro.serve.ContinuousBatchingEngine slots instead"
            )
        changed = 0
        if spec.model > 1:
            consumers: dict[Node, list[Node]] = {}
            for node in graph.toposort():
                for i in node.inputs:
                    if i is not None:
                        consumers.setdefault(i, []).append(node)
            for idx, n in enumerate(_shard_candidates(graph, desc)):
                split = _plan_split(n, spec.model, consumers)
                if split is None:
                    continue
                group = f"c{idx}|m|d{spec.data_rank}"
                gathered = _shard_node(n, split, spec, group)
                graph.replace_node(n, gathered)
                changed += 1
            if changed:
                changed += _sink_gathers(graph)
        if spec.data > 1:
            for i, out in enumerate(graph.outputs):
                g = ir.all_gather(
                    out,
                    0,
                    group=f"out{i}|d|m{spec.model_rank}",
                    rank=spec.data_rank,
                    parts=spec.data,
                )
                graph.outputs[i] = g
                changed += 1
            graph.invalidate()
        return changed

    return GraphPass(
        "shard",
        _shard,
        f"tensor/data-parallel split for mesh shard "
        f"(d{spec.data_rank}, m{spec.model_rank}) of "
        f"{spec.data}x{spec.model}",
    )


# ---------------------------------------------------------------------------
# Pipelines: per-mode pass-list configurations.
# ---------------------------------------------------------------------------


def _capability_filtered(rules, desc: AcceleratorDescription):
    """Capability negotiation for legalization: fusing a chain into a
    generalized op is only useful when the target can actually run the core
    op — a host-resident generalized op has no executor.  Chains whose core
    the description does not support stay as plain ops, which the host
    executes cleanly after partitioning."""
    from repro.core.rewrite import RewriteRule

    supported = desc.supported_ops()

    def filtered(r):
        def build(m: Match, graph: Graph, _build=r.build):
            core = m.captures.get("core")
            if core is not None:
                x = core.inputs[0] if core.inputs else None
                dtype = x.dtype if x is not None else core.dtype
                if core.op not in supported or not desc.supports_dtype(
                    core.op, dtype
                ):
                    return None
            return _build(m, graph)

        return RewriteRule(name=r.name, pattern=r.pattern, build=build)

    return tuple(filtered(r) for r in rules)


def frontend_passes(
    desc: AcceleratorDescription,
    *,
    legalize: bool = True,
    fold: bool = True,
    optimize: bool | None = None,
) -> list[GraphPass]:
    """Build the frontend pipeline as a pass list.  ``optimize`` defaults
    to ``legalize`` (the naive BYOC baseline runs neither)."""
    optimize = legalize if optimize is None else optimize
    passes: list[GraphPass] = []
    if optimize:
        passes.append(
            rewrite_pass(
                "fold_transpose",
                FOLD_TRANSPOSE_RULES,
                "compose/absorb layout transposes",
            )
        )
    if legalize:
        passes.append(
            rewrite_pass(
                "legalize",
                _capability_filtered(LEGALIZE_RULES, desc),
                "fuse chains into generalized ops",
            )
        )
        target_rules = tuple(getattr(desc, "rewrite_rules", ()) or ())
        if target_rules:
            passes.append(
                rewrite_pass(
                    "target_patterns",
                    target_rules,
                    f"{desc.name} description-contributed patterns",
                )
            )
    if optimize:
        passes.append(
            rewrite_pass("fuse_residual", RESIDUAL_RULES, "fuse skip-connection adds")
        )
        passes.append(
            rewrite_pass("fuse_conv_pool", CONV_POOL_RULES, "fuse pooling epilogues")
        )
    if fold:
        passes.append(
            GraphPass("fold_constants", _fold_constants, "evaluate const subgraphs")
        )
    if optimize:
        passes.append(GraphPass("cse", _cse, "deduplicate common subexpressions"))
        passes.append(GraphPass("dce", _dce, "drop no-effect nodes"))
    passes.append(GraphPass("partition", _partition, "mark accelerator regions"))
    return passes


def passes_for_mode(
    desc: AcceleratorDescription, mode: str, shard: ShardSpec | None = None
) -> list[GraphPass]:
    """The per-mode pipeline configuration (paper §4 evaluation matrix).
    ``naive`` is stock BYOC: partitioning only — no legalization, no
    folding, no graph optimization.  A ``shard`` spec (``Target(devices=
    N)``) inserts the shard-partitioning pass right before ``partition``
    in every mode; ``devices == 1`` compiles the identical pipeline (and
    thus a collective-free plan)."""
    if mode == "naive":
        passes = frontend_passes(desc, legalize=False, fold=False)
    else:
        passes = frontend_passes(desc)
    if shard is not None and shard.devices > 1:
        passes.insert(len(passes) - 1, make_shard_pass(shard))
    return passes


# ---------------------------------------------------------------------------
# Back-compat functional API (the pre-PassManager surface).
# ---------------------------------------------------------------------------


def legalize(graph: Graph) -> Graph:
    """Fuse op sequences into generalized operators (rules in priority
    order; the engine drives them to a fixed point)."""
    apply_rules(graph, LEGALIZE_RULES)
    return graph


def fold_constants(graph: Graph) -> Graph:
    _fold_constants(graph)
    return graph


def partition(graph: Graph, desc: AcceleratorDescription) -> Graph:
    _partition(graph, PassContext(desc=desc))
    return graph


def run_frontend(
    graph: Graph,
    desc: AcceleratorDescription,
    *,
    fold: bool = True,
    do_legalize: bool = True,
) -> Graph:
    """The Frontend Configurator's pass pipeline (§3.3) through the
    PassManager: legalization + optimization, constant folding, then graph
    partitioning.  Returns the (mutated) graph; use
    ``PassManager(frontend_passes(...)).run(graph, ...)`` directly when the
    instrumentation report is needed."""
    pm = PassManager(frontend_passes(desc, legalize=do_legalize, fold=fold))
    pm.run(graph, PassContext(desc=desc))
    return graph
