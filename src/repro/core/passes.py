"""Compiler passes of the integration flow (paper §3.3).

* ``legalize`` — the Frontend Configurator's legalization pass: rewrites the
  quantized multi-op sequence (dense -> bias_add -> requantize -> clip) and
  float sequences (dense -> bias_add [-> activation]) into *generalized*
  operators so TIR-level lowering sees a single op (§3.3 "we introduce
  generalized Relay operators ... a legalization pass rewrites the sequence
  into a single operator").

* ``fold_constants`` — evaluates constant subgraphs at compile time.  This
  is the pass the paper had to fight TVM for ("TVM typically disables
  constant folding for matched operators after graph partitioning"): all
  registered *constant* preprocessing (weight transposition, quantization)
  disappears from the runtime graph.  The naive BYOC mode skips it — and
  pays at run time, reproducing Table 2's blowup.

* ``partition`` — marks accelerator-supported operators (from the
  functional description) with ``target="accel"``; everything else remains
  on the host, mirroring BYOC graph partitioning.
"""

from __future__ import annotations

import numpy as np

from repro.core.accel import AcceleratorDescription
from repro.core.ir import Graph, Node, execute_node


def _single_consumer(n: Node, consumers) -> bool:
    return len(consumers.get(n, [])) == 1


def _gen_op_for(core: Node) -> str:
    return "generalized_dense" if core.op == "dense" else "generalized_conv2d"


def _fuse_quantized(graph: Graph) -> bool:
    """clip(requantize(bias_add(dense|conv2d))) -> one generalized op."""
    consumers = graph.consumers()
    for n in graph.toposort():
        if n.op != "clip" or n.inputs[0].op != "requantize":
            continue
        rq = n.inputs[0]
        if rq.inputs[0].op != "bias_add":
            continue
        ba = rq.inputs[0]
        core = ba.inputs[0]
        if core.op in ("dense", "conv2d") and all(
            _single_consumer(x, consumers) for x in (rq, ba, core)
        ):
            new = Node(
                _gen_op_for(core),
                [core.inputs[0], core.inputs[1], ba.inputs[1]],
                {
                    **core.attrs,
                    "quantized": True,
                    "requant_scale": rq.attrs["scale"],
                    "clip_lo": n.attrs["lo"],
                    "clip_hi": n.attrs["hi"],
                },
                shape=n.shape,
                dtype=n.dtype,
            )
            graph.replace_node(n, new)
            return True
    return False


def _fuse_activation(graph: Graph) -> bool:
    """activation(bias_add(dense|conv2d)) -> one generalized op."""
    consumers = graph.consumers()
    for n in graph.toposort():
        if n.op not in ("relu", "gelu") or n.inputs[0].op != "bias_add":
            continue
        ba = n.inputs[0]
        core = ba.inputs[0]
        if core.op in ("dense", "conv2d") and all(
            _single_consumer(x, consumers) for x in (ba, core)
        ):
            new = Node(
                _gen_op_for(core),
                [core.inputs[0], core.inputs[1], ba.inputs[1]],
                {**core.attrs, "quantized": False, "activation": n.op},
                shape=n.shape,
                dtype=n.dtype,
            )
            graph.replace_node(n, new)
            return True
    return False


def _fuse_bias(graph: Graph) -> bool:
    """bias_add(dense|conv2d) -> one generalized op (no epilogue)."""
    consumers = graph.consumers()
    for n in graph.toposort():
        if n.op != "bias_add":
            continue
        core = n.inputs[0]
        if core.op in ("dense", "conv2d") and _single_consumer(core, consumers):
            new = Node(
                _gen_op_for(core),
                [core.inputs[0], core.inputs[1], n.inputs[1]],
                {**core.attrs, "quantized": False, "activation": None},
                shape=n.shape,
                dtype=n.dtype,
            )
            graph.replace_node(n, new)
            return True
    return False


def legalize(graph: Graph) -> Graph:
    """Fuse op sequences into generalized operators.

    Rules run in priority order (longest pattern first) so the quantized
    chain is matched before its bias_add sub-pattern; each rule iterates to
    fixpoint before the next is tried.
    """
    for rule in (_fuse_quantized, _fuse_activation, _fuse_bias):
        while rule(graph):
            pass
    return graph


def fold_constants(graph: Graph) -> Graph:
    """Evaluate nodes whose inputs are all constants; iterate to fixpoint.

    Runs registered constant preprocessing (transpose/quantize on weights)
    at compile time — the key enabler the paper identifies in §4.
    """
    from repro.core.ir import const

    changed = True
    while changed:
        changed = False
        for n in graph.toposort():
            if n.op in ("input", "const") or n.op.startswith("generalized"):
                continue
            if n.inputs and all(i.is_const() for i in n.inputs):
                try:
                    val = execute_node(n, [i.value for i in n.inputs])
                except NotImplementedError:
                    continue
                folded = const(np.asarray(val), name=f"folded_{n.name}")
                graph.replace_node(n, folded)
                changed = True
                break
    return graph


def partition(graph: Graph, desc: AcceleratorDescription) -> Graph:
    """Mark accelerator-supported operators (BYOC-style partitioning)."""
    supported = desc.supported_ops()
    for n in graph.toposort():
        base = n.op.replace("generalized_", "")
        if base in supported and n.op != "input":
            n.target = "accel"
        else:
            n.target = "host"
    return graph


def run_frontend(
    graph: Graph,
    desc: AcceleratorDescription,
    *,
    fold: bool = True,
    do_legalize: bool = True,
) -> Graph:
    """The Frontend Configurator's pass pipeline (§3.3): legalization (with
    predefined supported operators from the functional description), then
    constant folding, then graph partitioning."""
    if do_legalize:
        graph = legalize(graph)
    if fold:
        graph = fold_constants(graph)
    graph = partition(graph, desc)
    return graph
