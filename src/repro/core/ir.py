"""Graph IR — the Relay stand-in for the integration flow (paper §3.3).

A small typed op-graph: nodes carry an op name, input edges, attributes and
an output (shape, dtype).  The frontend builds it; legalization rewrites
quantized multi-op sequences into generalized operators; partitioning marks
accelerator-supported regions; constant folding evaluates const subgraphs
(including registered preprocessing) at compile time.

Ops are deliberately the ones the paper's flow deals with: quantized dense
and conv sequences (QNN dense -> bias_add -> requantize -> clip), layout
preprocessing (transpose / reshape / im2col / quantize), elementwise ops
the host executes, and the *generalized* fused operators the legalization
pass introduces.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any

import numpy as np

_counter = itertools.count()

# Ops the host (XLA / CPU) executes; anything may appear here.
HOST_OPS = {
    "add",
    "sub",
    "mul",
    "relu",
    "gelu",
    "clip",
    "requantize",
    "quantize",
    "dequantize",
    "bias_add",
    "transpose",
    "reshape",
    "flatten",
    "im2col",
    "softmax",
    "max_pool2d",
    "shard_slice",
}

# Multi-op sequences the legalizer fuses into these generalized operators.
GENERALIZED_OPS = {"generalized_dense", "generalized_conv2d"}

# Cross-shard communication ops the shard-partitioning pass inserts
# (``passes.make_shard_pass``).  They carry ``group``/``rank``/``parts``
# attrs and execute as barrier+numpy reductions through a
# ``repro.core.collective.CollectiveSession``; ``shard_slice`` (a plain
# host op) is their shard-local counterpart.
COLLECTIVE_OPS = {"all_gather", "all_reduce", "reduce_scatter"}

# Stateful KV-cache ops for LM decode.  The IR stays functional: the cache
# is an ordinary graph input and ``kv_cache_append`` returns the updated
# cache as an ordinary output — the serve engine threads outputs back into
# the next step's feeds (``CacheSpec.state`` names the wiring).  They are
# host-resident by contract: the partitioner never offloads them, and the
# shard pass refuses graphs that contain them (capability negotiation for
# accelerators that only see stateless GEMM regions).
CACHE_OPS = {"kv_cache_read", "kv_cache_append"}
HOST_OPS |= CACHE_OPS


@dataclass(frozen=True)
class CacheSpec:
    """Decode-state contract carried on a :class:`Graph`.

    ``state`` maps each cache *input* name to the graph *output* index that
    carries its updated value, so a runtime can feed step N's cache outputs
    straight back as step N+1's cache inputs without knowing the model.
    ``layout`` is ``"LD"`` (``[max_len, d]`` per sample) or ``"BLD"`` with a
    leading batch dim; ``dtype`` is the stored KV dtype (int8-quantized KV
    per ``models/cache.py`` by default).
    """

    max_len: int
    dtype: str = "int8"
    layout: str = "LD"
    state: tuple[tuple[str, int], ...] = ()
    pos_input: str = "pos"
    mask_input: str = "mask"


@dataclass
class Node:
    op: str
    inputs: list["Node"]
    attrs: dict[str, Any] = field(default_factory=dict)
    shape: tuple[int, ...] = ()
    dtype: str = "float32"
    name: str = ""
    # set by partitioning: "accel" or "host"
    target: str = "host"
    # constant payload for "const" nodes
    value: np.ndarray | None = None

    def __post_init__(self):
        if not self.name:
            self.name = f"{self.op}_{next(_counter)}"

    def is_const(self) -> bool:
        return self.op == "const"

    def __repr__(self):
        ins = ", ".join(i.name for i in self.inputs)
        return f"{self.name}: {self.op}({ins}) -> {self.dtype}{list(self.shape)} [{self.target}]"

    # hash/eq by identity so nodes can live in sets/dicts while mutable
    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


@dataclass
class Graph:
    """A single-output dataflow graph (multi-output via the outputs list).

    The topological order and the consumers map are cached: the rewrite
    engine and the passes walk them every round, and recomputing a full
    DFS per query made the old fixed-point loops O(n^2).  Anything that
    mutates graph structure *through the Graph API* (``replace_node``)
    invalidates the caches automatically; code that rewires ``Node.inputs``
    or reassigns ``outputs`` directly must call ``invalidate()``.
    """

    outputs: list[Node]
    name: str = "graph"
    # decode-state contract for stateful (KV-cache) graphs; None otherwise
    cache_spec: CacheSpec | None = None
    _order: list[Node] | None = field(
        default=None, init=False, repr=False, compare=False
    )
    _consumers: dict[Node, list[Node]] | None = field(
        default=None, init=False, repr=False, compare=False
    )

    def invalidate(self) -> None:
        """Drop cached traversal state after a structural mutation."""
        self._order = None
        self._consumers = None

    def toposort(self) -> list[Node]:
        """Inputs-before-consumers order.  The returned list is the cache —
        treat it as read-only (it is replaced, never mutated, so iterating
        a snapshot across rewrites stays safe)."""
        if self._order is not None:
            return self._order
        seen: dict[Node, bool] = {}
        order: list[Node] = []

        def visit(n: Node):
            if n in seen:
                if not seen[n]:
                    raise ValueError("cycle in graph")
                return
            seen[n] = False
            for i in n.inputs:
                if i is not None:  # optional operands (e.g. absent bias)
                    visit(i)
            seen[n] = True
            order.append(n)

        for out in self.outputs:
            visit(out)
        self._order = order
        return order

    def nodes(self) -> list[Node]:
        return self.toposort()

    def inputs(self) -> list[Node]:
        return [n for n in self.toposort() if n.op == "input"]

    def consumers(self) -> dict[Node, list[Node]]:
        """Node -> consuming nodes (read-only; cached with the order)."""
        if self._consumers is not None:
            return self._consumers
        cons: dict[Node, list[Node]] = {n: [] for n in self.toposort()}
        for n in self.toposort():
            for i in n.inputs:
                if i is not None:
                    cons[i].append(n)
        self._consumers = cons
        return cons

    def replace_node(self, old: Node, new: Node) -> None:
        """Rewire every consumer of `old` to consume `new`."""
        for n in self.toposort():
            n.inputs = [new if i is old else i for i in n.inputs]
        self.outputs = [new if o is old else o for o in self.outputs]
        self.invalidate()

    def summary(self) -> str:
        lines = [f"graph {self.name}:"]
        for n in self.toposort():
            lines.append(f"  {n!r}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Builder API (what the frontend / examples use to construct graphs).
# ---------------------------------------------------------------------------


def input_(shape, dtype="float32", name="") -> Node:
    return Node("input", [], shape=tuple(shape), dtype=dtype, name=name or "")


def const(value: np.ndarray, name="") -> Node:
    value = np.asarray(value)
    return Node(
        "const",
        [],
        shape=tuple(value.shape),
        dtype=str(value.dtype),
        value=value,
        name=name or "",
    )


def _binary_shape(a: Node, b: Node) -> tuple[int, ...]:
    return np.broadcast_shapes(a.shape, b.shape)


def dense(x: Node, w: Node, **attrs) -> Node:
    """QNN/fp dense: x[..., C] @ w[C, K] (weights already in (C, K) layout).

    A 3-D ``w`` is the *batched* activation-activation matmul (attention
    scores/context with a leading batch dim): ``x[B, M, C] @ w[B, C, K]``.
    Weight-operand denses instead fold every leading dim of ``x`` into the
    GEMM M dimension, so a batched input IS the batched GEMM.
    """
    out_dtype = attrs.pop("out_dtype", "int32" if x.dtype.startswith("int") else x.dtype)
    if len(w.shape) == 3:
        if len(x.shape) != 3 or x.shape[0] != w.shape[0] or x.shape[-1] != w.shape[-2]:
            raise ValueError(f"batched dense shape mismatch {x.shape} @ {w.shape}")
        return Node(
            "dense",
            [x, w],
            attrs,
            shape=(x.shape[0], x.shape[1], w.shape[-1]),
            dtype=out_dtype,
        )
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"dense shape mismatch {x.shape} @ {w.shape}")
    return Node(
        "dense",
        [x, w],
        attrs,
        shape=(*x.shape[:-1], w.shape[1]),
        dtype=out_dtype,
    )


def conv2d(x: Node, w: Node, stride=1, padding=0, **attrs) -> Node:
    """NHWC conv with HWIO weights."""
    n, h, wd, c = x.shape
    kh, kw, ci, co = w.shape
    assert c == ci, (x.shape, w.shape)
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (wd + 2 * padding - kw) // stride + 1
    out_dtype = attrs.pop("out_dtype", "int32" if x.dtype.startswith("int") else x.dtype)
    return Node(
        "conv2d",
        [x, w],
        {"stride": stride, "padding": padding, **attrs},
        shape=(n, oh, ow, co),
        dtype=out_dtype,
    )


def bias_add(x: Node, b: Node) -> Node:
    return Node("bias_add", [x, b], shape=x.shape, dtype=x.dtype)


def requantize(x: Node, scale: float, out_dtype="int8") -> Node:
    return Node("requantize", [x], {"scale": scale}, shape=x.shape, dtype=out_dtype)


def clip(x: Node, lo=-128, hi=127) -> Node:
    return Node("clip", [x], {"lo": lo, "hi": hi}, shape=x.shape, dtype=x.dtype)


def quantize(x: Node, scale: float, dtype="int8") -> Node:
    return Node("quantize", [x], {"scale": scale}, shape=x.shape, dtype=dtype)


def dequantize(x: Node, scale: float) -> Node:
    return Node("dequantize", [x], {"scale": scale}, shape=x.shape, dtype="float32")


def transpose(x: Node, perm=None) -> Node:
    perm = tuple(perm) if perm is not None else tuple(reversed(range(len(x.shape))))
    shape = tuple(x.shape[p] for p in perm)
    return Node("transpose", [x], {"perm": perm}, shape=shape, dtype=x.dtype)


def reshape(x: Node, shape) -> Node:
    return Node("reshape", [x], {"shape": tuple(shape)}, shape=tuple(shape), dtype=x.dtype)


def flatten(x: Node) -> Node:
    n = x.shape[0]
    rest = int(np.prod(x.shape[1:]))
    return reshape(x, (n, rest))


def relu(x: Node) -> Node:
    return Node("relu", [x], shape=x.shape, dtype=x.dtype)


def gelu(x: Node) -> Node:
    return Node("gelu", [x], shape=x.shape, dtype=x.dtype)


def max_pool2d(x: Node, size: int = 2, stride: int | None = None) -> Node:
    """NHWC max pooling with a square window (no padding)."""
    stride = size if stride is None else stride
    n, h, w, c = x.shape
    oh = (h - size) // stride + 1
    ow = (w - size) // stride + 1
    return Node(
        "max_pool2d",
        [x],
        {"size": size, "stride": stride},
        shape=(n, oh, ow, c),
        dtype=x.dtype,
    )


def softmax(x: Node, axis: int = -1) -> Node:
    out_dtype = "float32" if x.dtype.startswith(("int", "uint")) else x.dtype
    return Node("softmax", [x], {"axis": axis}, shape=x.shape, dtype=out_dtype)


def shard_slice(x: Node, axis: int, rank: int, parts: int) -> Node:
    """This shard's ``rank``-th of ``parts`` equal slices of ``x`` along
    ``axis`` (the dimension must divide evenly — the shard pass only splits
    when it does)."""
    ax = axis % len(x.shape)
    if x.shape[ax] % parts:
        raise ValueError(
            f"shard_slice: dim {ax} of {x.shape} not divisible by {parts}"
        )
    shape = tuple(
        d // parts if i == ax else d for i, d in enumerate(x.shape)
    )
    return Node(
        "shard_slice",
        [x],
        {"axis": ax, "rank": rank, "parts": parts},
        shape=shape,
        dtype=x.dtype,
    )


def _collective(op: str, x: Node, shape, axis: int, group: str, rank: int, parts: int) -> Node:
    return Node(
        op,
        [x],
        {"group": group, "rank": rank, "parts": parts, "axis": axis},
        shape=tuple(shape),
        dtype=x.dtype,
    )


def all_gather(x: Node, axis: int, *, group: str, rank: int, parts: int) -> Node:
    """Concatenate every shard's ``x`` along ``axis`` (rank order)."""
    ax = axis % len(x.shape)
    shape = tuple(d * parts if i == ax else d for i, d in enumerate(x.shape))
    return _collective("all_gather", x, shape, ax, group, rank, parts)


def all_reduce(x: Node, *, group: str, rank: int, parts: int) -> Node:
    """Element-wise sum of every shard's ``x`` (same shape on every shard)."""
    return _collective("all_reduce", x, x.shape, 0, group, rank, parts)


def reduce_scatter(x: Node, axis: int, *, group: str, rank: int, parts: int) -> Node:
    """Sum every shard's ``x`` then keep this rank's slice along ``axis``."""
    ax = axis % len(x.shape)
    if x.shape[ax] % parts:
        raise ValueError(
            f"reduce_scatter: dim {ax} of {x.shape} not divisible by {parts}"
        )
    shape = tuple(d // parts if i == ax else d for i, d in enumerate(x.shape))
    return _collective("reduce_scatter", x, shape, ax, group, rank, parts)


def kv_cache_read(cache: Node) -> Node:
    """Materialize the full cache for attention (identity payload; marks the
    state consumption so it is costed and never folded into accel regions)."""
    return Node("kv_cache_read", [cache], shape=cache.shape, dtype=cache.dtype)


def kv_cache_append(cache: Node, update: Node, pos: Node) -> Node:
    """Functional append: write ``update``'s rows into ``cache`` along the
    sequence axis (-2) starting at ``pos``, returning the updated cache.

    Shapes: ``cache[..., L, D]``, ``update[..., S, D]`` with ``S <= L`` and
    matching leading/feature dims; ``pos`` is a scalar int32, or ``[B]`` for
    per-request positions on batched ``[B, L, D]`` caches (continuous
    batching appends each slot at its own length).  Writes must stay in
    bounds — the executor raises rather than clamping.
    """
    if update.dtype != cache.dtype:
        raise ValueError(
            f"kv_cache_append dtype mismatch: cache {cache.dtype} vs update {update.dtype}"
        )
    if (
        len(update.shape) != len(cache.shape)
        or update.shape[:-2] != cache.shape[:-2]
        or update.shape[-1] != cache.shape[-1]
        or update.shape[-2] > cache.shape[-2]
    ):
        raise ValueError(
            f"kv_cache_append shape mismatch: cache {cache.shape} vs update {update.shape}"
        )
    if pos.shape not in ((), cache.shape[:-2]):
        raise ValueError(
            f"kv_cache_append pos shape {pos.shape} for cache {cache.shape}"
        )
    return Node(
        "kv_cache_append", [cache, update, pos], shape=cache.shape, dtype=cache.dtype
    )


def kv_append_ref(cache: np.ndarray, update: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """The single append definition every execution path shares (interpreter
    and planned host closure must be bit-identical)."""
    out = np.array(cache)
    s = update.shape[-2]
    pos = np.asarray(pos)
    limit = cache.shape[-2]
    if pos.ndim == 0:
        p = int(pos)
        if p < 0 or p + s > limit:
            raise ValueError(f"kv_cache_append out of bounds: pos {p} + {s} > {limit}")
        out[..., p : p + s, :] = update
    else:
        for b, p in enumerate(pos.astype(np.int64).ravel()):
            p = int(p)
            if p < 0 or p + s > limit:
                raise ValueError(
                    f"kv_cache_append out of bounds: pos {p} + {s} > {limit} (slot {b})"
                )
            out[b, ..., p : p + s, :] = update[b]
    return out


def add(a: Node, b: Node) -> Node:
    return Node("add", [a, b], shape=_binary_shape(a, b), dtype=a.dtype)


def sub(a: Node, b: Node) -> Node:
    return Node("sub", [a, b], shape=_binary_shape(a, b), dtype=a.dtype)


def mul(a: Node, b: Node) -> Node:
    return Node("mul", [a, b], shape=_binary_shape(a, b), dtype=a.dtype)


# ---------------------------------------------------------------------------
# Reference executor (host semantics; used by tests and constant folding).
# ---------------------------------------------------------------------------


def gelu_ref(x: np.ndarray) -> np.ndarray:
    """The single gelu definition (tanh approximation) every execution path
    shares — the interpreter, the host-op fast path, and the fused
    generalized-op epilogues must be bit-identical."""
    xf = x.astype(np.float64)
    inner = np.sqrt(2.0 / np.pi) * (xf + 0.044715 * xf**3)
    return 0.5 * xf * (1.0 + np.tanh(inner))


def max_pool2d_ref(x: np.ndarray, size: int, stride: int) -> np.ndarray:
    """NHWC window max, exact for every dtype (pure comparisons)."""
    n, h, w, c = x.shape
    oh = (h - size) // stride + 1
    ow = (w - size) // stride + 1
    out = x[:, : oh * stride : stride, : ow * stride : stride, :]
    for i in range(size):
        for j in range(size):
            if i == 0 and j == 0:
                continue
            out = np.maximum(
                out, x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            )
    return out


def execute_node(n: Node, inputs: list[np.ndarray]) -> np.ndarray:
    op = n.op
    if op == "const":
        return n.value
    if op == "dense":
        x, w = inputs
        if n.attrs.get("transpose_b"):
            w = w.swapaxes(-2, -1)
        return (x.astype(np.int64) @ w.astype(np.int64)).astype(n.dtype) if n.dtype.startswith("int") else (x @ w).astype(n.dtype)
    if op == "conv2d":
        x, w = inputs
        s, p = n.attrs["stride"], n.attrs["padding"]
        if p:
            x = np.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
        nb, h, wd, c = x.shape
        kh, kw, _, co = w.shape
        oh = (h - kh) // s + 1
        ow = (wd - kw) // s + 1
        acc_dt = np.int64 if n.dtype.startswith("int") else np.float64
        out = np.zeros((nb, oh, ow, co), dtype=acc_dt)
        for i in range(kh):
            for j in range(kw):
                patch = x[:, i : i + oh * s : s, j : j + ow * s : s, :].astype(acc_dt)
                out += np.einsum("nhwc,co->nhwo", patch, w[i, j].astype(acc_dt))
        return out.astype(n.dtype)
    if op == "bias_add":
        return (inputs[0].astype(np.int64) + inputs[1].astype(np.int64)).astype(n.dtype) if n.dtype.startswith("int") else inputs[0] + inputs[1]
    if op == "requantize":
        # TVM QNN semantics: scale then *saturating* cast to the out dtype.
        out = np.round(inputs[0].astype(np.float64) * n.attrs["scale"])
        if n.dtype.startswith("int") or n.dtype.startswith("uint"):
            info = np.iinfo(n.dtype)
            out = np.clip(out, info.min, info.max)
        return out.astype(n.dtype)
    if op == "clip":
        return np.clip(inputs[0], n.attrs["lo"], n.attrs["hi"]).astype(n.dtype)
    if op == "quantize":
        return np.clip(
            np.round(inputs[0] / n.attrs["scale"]), -128, 127
        ).astype(n.dtype)
    if op == "dequantize":
        return inputs[0].astype(np.float32) * n.attrs["scale"]
    if op == "transpose":
        return np.transpose(inputs[0], n.attrs["perm"])
    if op == "reshape":
        return inputs[0].reshape(n.attrs["shape"])
    if op == "flatten":
        return inputs[0].reshape(n.shape)
    if op == "relu":
        return np.maximum(inputs[0], 0)
    if op == "gelu":
        return gelu_ref(inputs[0]).astype(n.dtype)
    if op == "max_pool2d":
        return max_pool2d_ref(inputs[0], n.attrs["size"], n.attrs["stride"])
    if op == "softmax":
        ax = n.attrs.get("axis", -1)
        x = inputs[0].astype(np.float64)
        e = np.exp(x - np.max(x, axis=ax, keepdims=True))
        return (e / np.sum(e, axis=ax, keepdims=True)).astype(n.dtype)
    if op == "shard_slice":
        ax, rank, parts = n.attrs["axis"], n.attrs["rank"], n.attrs["parts"]
        size = inputs[0].shape[ax] // parts
        idx = [slice(None)] * inputs[0].ndim
        idx[ax] = slice(rank * size, (rank + 1) * size)
        return inputs[0][tuple(idx)]
    if op in COLLECTIVE_OPS:
        # single-participant reference semantics (identity gather / sum of
        # one / keep-own-slice); the multi-shard rendezvous lives in the
        # planned executor (``collective.collective_fn``)
        if n.attrs["parts"] > 1:
            raise NotImplementedError(
                f"{op} with parts > 1 executes via a CollectiveSession"
            )
        return inputs[0].astype(n.dtype)
    if op == "kv_cache_read":
        return np.asarray(inputs[0])
    if op == "kv_cache_append":
        return kv_append_ref(inputs[0], inputs[1], inputs[2])
    if op == "add":
        return inputs[0] + inputs[1]
    if op == "sub":
        return inputs[0] - inputs[1]
    if op == "mul":
        return inputs[0] * inputs[1]
    if op == "generalized_dense":
        x, w, b = inputs[:3]
        if n.attrs.get("transpose_b"):
            w = w.swapaxes(-2, -1)
        # integer operands always accumulate wide (the systolic-array
        # semantics); int32-wrapping on the final cast matches the unfused
        # dense + bias_add chain exactly (mod-2^32 addition commutes).
        if n.attrs.get("quantized") or x.dtype.kind in "iu":
            acc = x.astype(np.int64) @ w.astype(np.int64)
        else:
            acc = x @ w
        if b is not None:
            acc = acc + b
        if n.attrs.get("quantized"):
            acc = np.round(acc.astype(np.float64) * n.attrs["requant_scale"])
            acc = np.clip(acc, n.attrs["clip_lo"], n.attrs["clip_hi"])
        elif n.attrs.get("activation") == "relu":
            acc = np.maximum(acc, 0)
        elif n.attrs.get("activation") == "gelu":
            acc = gelu_ref(acc)
        out = acc.astype(n.dtype)
        if len(inputs) > 3 and inputs[3] is not None:
            out = out + inputs[3]  # fused residual epilogue
        return out
    if op == "generalized_conv2d":
        # evaluated through its dense form after im2col by the executor
        raise NotImplementedError("generalized_conv2d executes via backend lowering")
    raise NotImplementedError(f"execute_node: {op}")


def clone_graph(graph: Graph) -> Graph:
    """A structural deep copy: fresh ``Node`` objects wired like the
    originals, in the SAME topological order and with the SAME names (so
    per-shard clones number their nodes identically — the shard pass keys
    collective groups by toposort position).  Attr dicts are copied deep
    enough to mutate independently; const arrays are shared (read-only by
    convention)."""
    import copy

    mapping: dict[Node, Node] = {}
    for n in graph.toposort():
        c = Node(
            n.op,
            [mapping[i] if i is not None else None for i in n.inputs],
            copy.deepcopy(n.attrs),
            shape=n.shape,
            dtype=n.dtype,
            name=n.name,
            target=n.target,
            value=n.value,
        )
        mapping[n] = c
    return Graph(
        [mapping[o] for o in graph.outputs],
        name=graph.name,
        cache_spec=graph.cache_spec,
    )


def execute_graph(graph: Graph, feeds: dict[str, np.ndarray]) -> list[np.ndarray]:
    vals: dict[Node, np.ndarray] = {}
    for n in graph.toposort():
        if n.op == "input":
            if n.name not in feeds:
                raise KeyError(f"missing feed for input {n.name!r}")
            vals[n] = np.asarray(feeds[n.name])
        else:
            vals[n] = execute_node(n, [vals[i] if i is not None else None for i in n.inputs])
    return [vals[o] for o in graph.outputs]
