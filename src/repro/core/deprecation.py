"""Deprecation plumbing for the legacy two-step API.

The repo-specific warning class exists so the test suite can turn *our*
deprecations into hard errors (``filterwarnings`` in ``pyproject.toml``)
without tripping over DeprecationWarnings raised by third-party imports.
"""

from __future__ import annotations

import warnings


class ReproDeprecationWarning(DeprecationWarning):
    """A repro API is deprecated in favor of the ``repro.compile()`` front
    door.  Subclassing ``DeprecationWarning`` keeps standard tooling
    (``python -W``, pytest) able to address it generically."""


def warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} instead "
        f"(see docs/integration_guide.md)",
        ReproDeprecationWarning,
        stacklevel=3,
    )
