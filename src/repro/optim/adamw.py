"""AdamW + cosine schedule, pure JAX (no optax dependency).

Moment dtype is configurable: f32 default; bf16 moments halve optimizer
memory for the largest dry-run cells (recorded in EXPERIMENTS §Dry-run).
Global-norm clipping included.  State is a pytree mirroring params, so the
ZeRO-style sharding rules in ``repro.parallel`` apply to it directly.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: str = "float32"


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(cfg: AdamWConfig, params):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
