"""Activation-sharding policy: explicit GSPMD constraints at key points.

Without these, sharding propagation can pick a parameter-centric layout
(e.g. the FSDP dim of the embedding table) and carry a *replicated batch*
through the whole model — observed as 12 GiB logits buffers with the
global batch unsharded.  The launcher installs a policy describing the
mesh's dp/tp axes; model code calls ``constrain`` at the few points that
anchor propagation (embed output, scan carries, MoE buffers, logits).

No-op when no policy is installed (single-device tests/examples).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P

_lock = threading.Lock()
_POLICY: "ActivationPolicy | None" = None


@dataclass(frozen=True)
class ActivationPolicy:
    dp: tuple[str, ...]  # data-parallel axes ("pod","data") or ("data",)
    tp: str  # tensor-parallel axis name
    dp_size: int
    tp_size: int
    # layer-boundary residual-stream sharding: "seq" = Megatron-SP style
    # (S over model between blocks), "none" = batch-only (§Perf knob)
    boundary: str = "seq"


def install(mesh, *, boundary: str = "seq") -> ActivationPolicy:
    from repro.parallel.sharding import dp_axes

    dp = tuple(dp_axes(mesh))
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    pol = ActivationPolicy(
        dp=dp,
        tp="model",
        dp_size=dp_size,
        tp_size=mesh.shape.get("model", 1),
        boundary=boundary,
    )
    set_policy(pol)
    return pol


def set_policy(p: ActivationPolicy | None) -> None:
    global _POLICY
    with _lock:
        _POLICY = p


def get_policy() -> ActivationPolicy | None:
    return _POLICY


def constrain(x: jax.Array, *dims: str | None) -> jax.Array:
    """Apply a sharding constraint described symbolically.

    dims entries: "dp" (data axes), "tp" (model axis), "boundary" (model
    axis iff the policy's boundary mode is "seq"), None (replicated).
    Axes that do not divide the corresponding dimension are dropped.
    """
    pol = get_policy()
    if pol is None:
        return x
    spec = []
    for dim_size, d in zip(x.shape, dims):
        if d == "boundary":
            d = "tp" if pol.boundary == "seq" else None
        if d == "dp" and dim_size % pol.dp_size == 0:
            spec.append(pol.dp)
        elif d == "tp" and dim_size % pol.tp_size == 0:
            spec.append(pol.tp)
        else:
            spec.append(None)
    spec.extend([None] * (x.ndim - len(spec)))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x  # no mesh context (plain jit): constraint is advisory
