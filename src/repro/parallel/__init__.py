from repro.parallel.sharding import (
    batch_spec,
    cache_specs,
    logits_spec,
    opt_state_specs,
    param_specs,
)

__all__ = [
    "param_specs",
    "opt_state_specs",
    "batch_spec",
    "cache_specs",
    "logits_spec",
]
