"""Sharding rules: parameter / optimizer / activation PartitionSpecs.

Parallelism map (see DESIGN §5):
  * ``model`` axis — tensor parallelism: attention heads, d_ff, vocab,
    MoE experts (expert parallelism when E divides the axis, else TP
    inside each expert).
  * ``data`` (+ ``pod``) axes — batch data parallelism; with
    ``fsdp=True`` parameters/optimizer state are *also* sharded over the
    data axes on a non-TP dimension (ZeRO-3 style storage; GSPMD inserts
    per-layer all-gathers inside the scan).
  * decode caches shard batch over data and heads over model when the KV
    head count divides the axis, otherwise the *sequence* dim shards over
    model (sequence-parallel decode attention: partial softmax + psum,
    inserted automatically by GSPMD from the jnp decode path).

Every rule checks divisibility against the actual mesh axis sizes and
falls back to replication per-dimension, so any mesh shape that factors
(pod, data, model) works — the elastic-resume path re-derives specs for
whatever device count is available.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def _axsize(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axsize(mesh, n)
        return out
    return mesh.shape.get(name, 1)


def dp_axes(mesh: Mesh):
    """The data-parallel meta-axis: ('pod','data') on multi-pod meshes."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _div(dim: int, mesh: Mesh, ax) -> Any:
    """Return ax if dim is divisible by its size (else None = replicate)."""
    return ax if dim % max(_axsize(mesh, ax), 1) == 0 and dim > 0 else None


def _spec2(mesh, shape, ax0, ax1) -> P:
    return P(_div(shape[0], mesh, ax0), _div(shape[1], mesh, ax1))


def param_specs(cfg: ModelConfig, params, mesh: Mesh, *, fsdp: bool = True):
    """PartitionSpec pytree matching `params` (init_lm layout)."""
    dp = tuple(dp_axes(mesh)) if fsdp else None
    tp = "model"

    def rule(path: str, x) -> P:
        shape = x.shape
        nd = x.ndim
        stacked = path.startswith("groups/")  # leading group-stack axis
        if stacked:
            shape = shape[1:]
            nd -= 1

        def out(*axes) -> P:
            axes = tuple(axes) + (None,) * (nd - len(axes))
            if stacked:
                axes = (None,) + axes
            return P(*axes)

        leaf = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""

        if nd == 0:
            return out()
        if nd == 1:
            # biases / norm scales: shard TP-dim biases when they match a
            # TP-sharded output dim; otherwise replicate (cheap).
            return out(_div(shape[0], mesh, tp) if shape[0] >= 1024 else None)

        # --- embeddings / head -------------------------------------------
        if parent == "embed" or (parent == "head" and leaf == "w"):
            if parent == "embed":  # [V, d]
                return out(_div(shape[0], mesh, tp), _div(shape[1], mesh, dp))
            return out(_div(shape[0], mesh, dp), _div(shape[1], mesh, tp))  # [d, V]

        # --- MoE expert banks [E, d, ff] / [E, ff, d] ----------------------
        if nd == 3:
            e = shape[0]
            if e % max(_axsize(mesh, tp), 1) == 0:
                # expert parallelism; FSDP on the middle dim
                return out(tp, _div(shape[1], mesh, dp), None)
            # TP inside experts on the ff dim
            ff_dim = 2 if leaf in ("gate", "up") else 1
            axes: list[Any] = [None, None, None]
            axes[ff_dim] = _div(shape[ff_dim], mesh, tp)
            axes[2 if ff_dim == 1 else 1] = _div(shape[2 if ff_dim == 1 else 1], mesh, dp)
            return out(*axes)

        # --- 2-D weights ----------------------------------------------------
        if leaf == "w":
            import os

            if (
                parent in ("w_in", "r")
                and shape[0] <= 1024
                and os.environ.get("REPRO_REPLICATE_SMALL_RECURRENT", "0") == "1"
            ):
                # §Perf knob: tiny recurrent gate weights (sLSTM) replicated
                # so the sequential scan has no per-step weight collectives
                return out(None, None)
            if parent in ("q", "k", "v", "gate", "up", "k_up", "v_up", "in_proj", "dt_proj", "w_in", "r"):
                # column-parallel: output dim on TP, input dim on FSDP
                return out(_div(shape[0], mesh, dp), _div(shape[1], mesh, tp))
            if parent in ("o", "down", "out_proj", "out"):
                # row-parallel: input dim on TP (psum after), output on FSDP
                return out(_div(shape[0], mesh, tp), _div(shape[1], mesh, dp))
            if parent in ("kv_down", "x_proj", "router", "i_gate", "f_gate", "o_gate"):
                return out(_div(shape[0], mesh, dp), None)  # small projections
            return out(_div(shape[0], mesh, dp), None)
        # mamba/xlstm odd tensors: conv_w [K, d_in], A_log [d_in, n]
        if leaf == "conv_w":
            return out(None, _div(shape[1], mesh, tp))
        if leaf == "A_log":
            return out(_div(shape[0], mesh, tp), None)
        return out(*(None,) * nd)

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
        return rule(prefix.rstrip("/"), tree)

    return walk(params)


def opt_state_specs(cfg: ModelConfig, opt_state, pspecs):
    """Optimizer moments mirror the parameter specs (ZeRO via FSDP dims)."""
    return {
        "m": pspecs,
        "v": pspecs,
        "step": P(),
    }


def batch_spec(mesh: Mesh) -> P:
    return P(tuple(dp_axes(mesh)))


def logits_spec(mesh: Mesh) -> P:
    return P(tuple(dp_axes(mesh)), None, "model")


def cache_specs(cfg: ModelConfig, cache, mesh: Mesh):
    """Decode-cache specs: batch on data; heads on model if divisible,
    else sequence-parallel (S on model)."""
    dp = tuple(dp_axes(mesh))
    tp = "model"
    tp_size = _axsize(mesh, tp)

    def rule(path: str, x) -> P:
        nd = x.ndim
        stacked = path.startswith("groups/")
        shape = x.shape[1:] if stacked else x.shape
        ndl = nd - (1 if stacked else 0)

        def out(*axes) -> P:
            axes = tuple(axes) + (None,) * (ndl - len(axes))
            if stacked:
                axes = (None,) + axes
            return P(*axes)

        def out(*axes) -> P:  # redefined with truncation to the leaf rank
            axes = tuple(axes)[:ndl] + (None,) * max(ndl - len(axes), 0)
            if stacked:
                axes = (None,) + axes
            return P(*axes)

        leaf = path.split("/")[-1]
        if ndl == 0:
            return P()
        b = shape[0]
        bdp = _div(b, mesh, dp)
        if leaf in ("k", "v", "k_scale", "v_scale"):  # [B, Hkv, S, dh?]
            if shape[1] % tp_size == 0:
                return out(bdp, tp, None, None)
            return out(bdp, None, _div(shape[2], mesh, tp), None)
        if leaf in ("latent", "k_rope"):  # [B, S, r] — sequence-parallel
            return out(bdp, _div(shape[1], mesh, tp), None)
        if leaf == "h":  # mamba state [B, d_in, n]
            return out(bdp, _div(shape[1], mesh, tp), None)
        if leaf == "conv":  # [B, K-1, d_in]
            return out(bdp, None, _div(shape[2], mesh, tp))
        if leaf == "c" and ndl == 4:  # mlstm [B, H, dh, dh]
            return out(bdp, _div(shape[1], mesh, tp), None, None)
        if leaf in ("n", "m", "c") and ndl >= 2:  # small recurrent states
            return out(bdp)
        if leaf == "len":
            return P()
        return out(bdp)

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
        return rule(prefix.rstrip("/"), tree)

    return walk(cache)


def shard_tree(tree, specs, mesh: Mesh):
    """device_put a host pytree according to spec pytree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )
